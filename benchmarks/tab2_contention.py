"""[Appendix A] Parallel graph-construction contention.

Paper: building CUDA graphs from multiple threads barely improves wall time;
per-driver-call latency rises with thread count. JAX analogue: concurrent
XLA compiles from Python threads contend (GIL + compiler locks). Same
experiment: N threads x M compiles, report wall time and per-compile latency.
"""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp

from benchmarks.common import fresh_jax_caches


def _distinct_fns(n):
    """n structurally distinct programs (defeat the jit cache)."""
    fns = []
    for i in range(n):
        k = i + 2

        def f(x, k=k):
            for _ in range(3):
                x = jnp.tanh(x @ x.T) * k
            return x.sum()
        fns.append(f)
    return fns


def run():
    rows = []
    n_programs = 16
    x = jnp.ones((64, 64), jnp.float32)
    for n_threads in (1, 2, 4, 8):
        fresh_jax_caches()
        fns = _distinct_fns(n_programs)
        lat = []
        lock = threading.Lock()

        def worker(chunk):
            for f in chunk:
                t0 = time.perf_counter()
                jax.jit(f).lower(x).compile()
                dt = time.perf_counter() - t0
                with lock:
                    lat.append(dt)

        chunks = [fns[i::n_threads] for i in range(n_threads)]
        t0 = time.perf_counter()
        ts = [threading.Thread(target=worker, args=(c,)) for c in chunks]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        wall = time.perf_counter() - t0
        per_call = sum(lat) / len(lat)
        rows.append((f"tab2.threads{n_threads}.wall", wall * 1e6,
                     f"per_compile={per_call * 1e3:.1f}ms"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="tab2_contention")

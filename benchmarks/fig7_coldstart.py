"""[Fig 7] Cold-start latency: vanilla capture vs Foundry LOAD vs eager.

Paper result: Foundry cuts engine init by 95-99% vs vLLM-with-graphs and is
comparable to or faster than eager (no-graphs) startup. We measure the same
three modes per model and report the reduction percentage.
"""
from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, fresh_jax_caches, make_engine, timed


def run():
    rows = []
    for arch in BENCH_ARCHS:
        eng = make_engine(arch)
        archive, _ = eng.save_archive()  # offline SAVE (not on the clock)

        fresh_jax_caches()
        eng_v = make_engine(arch)
        t_vanilla, rep_v = timed(eng_v.cold_start_vanilla)

        fresh_jax_caches()
        eng_e = make_engine(arch)
        t_eager, _ = timed(eng_e.cold_start_eager)
        # eager defers cost to the first decode step: charge it
        r = eng_e.submit([1, 2, 3], 1)
        t_eager_first, _ = timed(eng_e.run_until_drained)

        fresh_jax_caches()
        eng_f = make_engine(arch)
        t_foundry, rep_f = timed(eng_f.cold_start_foundry, archive,
                                 background_exact=False)

        reduction = 100.0 * (1 - t_foundry / t_vanilla)
        rows.append((f"fig7.{arch}.vanilla_s", t_vanilla * 1e6,
                     f"{len(eng_v.buckets)}buckets"))
        rows.append((f"fig7.{arch}.eager_s", t_eager * 1e6,
                     f"first_token={t_eager_first:.2f}s"))
        rows.append((f"fig7.{arch}.foundry_s", t_foundry * 1e6,
                     f"reduction={reduction:.1f}%"))
        rows.append((f"fig7.{arch}.templates", rep_f.n_templates,
                     f"of_{rep_f.n_buckets}_buckets"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="fig7_coldstart")

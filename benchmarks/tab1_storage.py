"""[Table 1 + §5.3] Storage cost + archive parse time + depot dedup.

Paper: Foundry archive 4-5x smaller than the process-checkpoint image
(templates + binaries vs everything); binary graph serialization parses 512
graphs in <100 ms where JSON took seconds. We compare:
  * templated archive vs serialize-every-bucket archive (checkpoint-image
    analogue),
  * binary (msgpack+zstd) vs JSON manifest parse time,
  * a model zoo's capture sets as N standalone archives vs ONE
    content-addressed TemplateDepot (core/depot.py): bytes on disk + dedup
    ratio — topology templates and StableHLO exports repeat across the
    bucket-ladder variants each model ships (canonicalized exports,
    core/materialize.py, make the repeats byte-identical).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from benchmarks.common import BENCH_ARCHS, make_engine, timed
from repro.core import Archive, TemplateDepot


def run():
    rows = []
    arch = BENCH_ARCHS[0]
    eng = make_engine(arch)  # bucket_mode="all": 16 buckets at reduced scale
    ar_templated, _ = eng.save_archive()
    ar_all, _ = eng.save_archive(serialize_all_executables=True)

    b_t = len(ar_templated.to_bytes())
    b_a = len(ar_all.to_bytes())
    rows.append(("tab1.archive_templated_bytes", b_t,
                 f"{len(eng.buckets)}buckets"))
    rows.append(("tab1.archive_image_bytes", b_a,
                 f"ratio={b_a / b_t:.2f}x"))

    # parse time: binary container vs JSON manifest
    raw = ar_templated.to_bytes()
    t_bin, _ = timed(Archive.from_bytes, raw)
    manifest_json = json.dumps(ar_templated.manifest, default=str)
    t_json, _ = timed(json.loads, manifest_json)
    # JSON can't hold blobs natively; hex-encode to emulate a pure-JSON store
    blob_json = json.dumps({h: b.hex() for h, b in ar_templated.blobs.items()})
    t_json_blobs, _ = timed(json.loads, blob_json)
    rows.append(("tab1.parse_binary", t_bin * 1e6, "verify+decompress"))
    rows.append(("tab1.parse_json", (t_json + t_json_blobs) * 1e6,
                 f"ratio={(t_json + t_json_blobs) / max(t_bin, 1e-9):.2f}x"))

    # --- depot: the model zoo's capture sets, standalone vs shared store --
    # each arch ships two capture sets (the pow2 ladder for latency tiers,
    # the dense ladder for throughput tiers) — buckets common to both
    # ladders produce byte-identical export blobs, which the depot stores
    # once. Standalone archives each carry their own copy.
    depot = TemplateDepot(os.path.join(tempfile.mkdtemp(), "depot"))
    standalone_bytes = 0
    n_archives = 0
    for a in BENCH_ARCHS:
        for ladder in ("pow2", "all"):
            ar, _ = make_engine(a, max_batch=8, max_seq=48,
                                bucket_mode=ladder).save_archive()
            standalone_bytes += len(ar.to_bytes())
            depot.put_archive(f"{a}-{ladder}", ar)
            n_archives += 1
    st = depot.stats()
    depot_bytes = sum(
        os.path.getsize(os.path.join(dirpath, f))
        for dirpath, _, files in os.walk(depot.root) for f in files)
    rows.append(("tab1.depot_standalone_bytes", standalone_bytes,
                 f"{n_archives}archives"))
    rows.append(("tab1.depot_bytes", depot_bytes,
                 f"blobs+manifests+index;ratio="
                 f"{standalone_bytes / max(depot_bytes, 1):.2f}x"))
    rows.append(("tab1.depot_dedup_ratio", st["dedup_ratio"],
                 f"{st['logical_blobs']}refs->{st['blobs']}blobs"))
    assert st["dedup_ratio"] > 1.0, \
        "depot found nothing to share across the zoo's capture sets"
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="tab1_storage")

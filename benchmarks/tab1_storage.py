"""[Table 1 + §5.3] Storage cost + archive parse time.

Paper: Foundry archive 4-5x smaller than the process-checkpoint image
(templates + binaries vs everything); binary graph serialization parses 512
graphs in <100 ms where JSON took seconds. We compare:
  * templated archive vs serialize-every-bucket archive (checkpoint-image
    analogue),
  * binary (msgpack+zstd) vs JSON manifest parse time.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import BENCH_ARCHS, make_engine, timed
from repro.core import Archive


def run():
    rows = []
    arch = BENCH_ARCHS[0]
    eng = make_engine(arch)  # bucket_mode="all": 16 buckets at reduced scale
    ar_templated, _ = eng.save_archive()
    ar_all, _ = eng.save_archive(serialize_all_executables=True)

    b_t = len(ar_templated.to_bytes())
    b_a = len(ar_all.to_bytes())
    rows.append(("tab1.archive_templated_bytes", b_t,
                 f"{len(eng.buckets)}buckets"))
    rows.append(("tab1.archive_image_bytes", b_a,
                 f"ratio={b_a / b_t:.2f}x"))

    # parse time: binary container vs JSON manifest
    raw = ar_templated.to_bytes()
    t_bin, _ = timed(Archive.from_bytes, raw)
    manifest_json = json.dumps(ar_templated.manifest, default=str)
    t_json, _ = timed(json.loads, manifest_json)
    # JSON can't hold blobs natively; hex-encode to emulate a pure-JSON store
    blob_json = json.dumps({h: b.hex() for h, b in ar_templated.blobs.items()})
    t_json_blobs, _ = timed(json.loads, blob_json)
    rows.append(("tab1.parse_binary", t_bin * 1e6, "verify+decompress"))
    rows.append(("tab1.parse_json", (t_json + t_json_blobs) * 1e6,
                 f"ratio={(t_json + t_json_blobs) / max(t_bin, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="tab1_storage")

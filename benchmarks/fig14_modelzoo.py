"""[Fig 14] Model zoo behind one gateway: scale-to-zero vs keep-resident.

The serverless/multi-model framing of the paper's thesis (§1-2, §4.4;
HydraServe and "Breaking the Ice" in PAPERS.md): when many models share a
fleet and popularity shifts, the operator either keeps every model resident
(paying peak memory for idle models) or scales idle models to zero and pays
their cold start on reactivation. Foundry makes the second option viable.

Two gateways replay the same popularity-shifting workload over the same
model set:

  vanilla   keep-everything-resident: every model's fleet is activated up
            front with full trace+lower+compile cold starts and NEVER
            released — activation latency is the compile, peak resident
            replicas is one per model, always;
  foundry   scale-to-zero: models activate lazily from ONE shared
            TemplateDepot (content-addressed blobs, fetched once
            process-wide), drain to zero replicas when idle, and reactivate
            via LOAD when their turn comes back.

Asserted, not just printed: foundry reactivation reaches READY faster than
vanilla activation, never compiles on the critical path
(fallback_compiles == 0), and token streams across a deactivate->reactivate
cycle are byte-identical to a never-deactivated engine. Reported: activation
latencies, peak resident replicas, depot dedup ratio.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import fresh_jax_caches, make_engine
from repro.core import TemplateDepot
from repro.serving.fleet import AutoscalePolicy
from repro.serving.router import ModelPolicy, ModelRouter

MODELS = ["smollm-360m", "qwen3-14b", "llama3.2-3b"]
PROMPT = [5, 9, 2]


def _factory(arch: str):
    return lambda: make_engine(arch, max_batch=4, max_seq=32,
                               bucket_mode="pow2")


def _policy(scale_to_zero: bool) -> ModelPolicy:
    return ModelPolicy(
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=2,
                                  target_inflight_per_replica=8,
                                  scale_down_idle_ticks=6),
        scale_to_zero=scale_to_zero, idle_ticks_to_zero=40)


def run(quick: bool = False):
    models = MODELS[:2] if quick else MODELS
    rounds = 2
    reqs_per_phase = 2 if quick else 4
    rows = []

    # ---- offline: one shared depot for the whole zoo ---------------------
    depot = TemplateDepot(os.path.join(tempfile.mkdtemp(), "depot"))
    for name in models:
        ar, _ = _factory(name)().save_archive()
        depot.put_archive(name, ar)
        fresh_jax_caches()
    st = depot.stats()
    rows.append(("fig14.depot.dedup_ratio", st["dedup_ratio"],
                 f"{st['archives']}archives;{st['blobs']}blobs;"
                 f"{st['physical_comp_bytes']}B_on_disk"))

    # ---- reference token streams: never-deactivated vanilla engines ------
    ref = {}
    for name in models:
        eng = _factory(name)()
        eng.cold_start_vanilla()
        r = eng.submit(PROMPT, 6)
        eng.run_until_drained()
        ref[name] = list(r.generated)

    phases = [(name, reqs_per_phase) for _ in range(rounds) for name in models]

    # ---- leg 1: vanilla keep-everything-resident -------------------------
    fresh_jax_caches()
    router_v = ModelRouter()
    for name in models:
        router_v.add_model(name, _factory(name), mode="vanilla",
                           policy=_policy(scale_to_zero=False))
        router_v.activate(name)  # resident from t0: pay every compile up front
    router_v.run_phases(phases, seed=0, gap_ticks=60)
    rep_v = router_v.report()
    v_act = [t for m in rep_v.models.values()
             for t in m["activation_ready_s"]]
    rows.append(("fig14.vanilla.activation_ready_s",
                 max(v_act) * 1e6, f"compile;n={len(v_act)}"))
    rows.append(("fig14.vanilla.peak_resident_replicas",
                 float(rep_v.peak_resident_replicas),
                 f"{len(models)}_models_always_resident"))
    router_v.deactivate_all()

    # ---- leg 2: foundry scale-to-zero from the shared depot --------------
    fresh_jax_caches()
    router_f = ModelRouter()
    for name in models:
        router_f.add_model(name, _factory(name), archive=depot.open(name),
                           policy=_policy(scale_to_zero=True))
    # gap > idle_ticks_to_zero: every popularity shift deterministically
    # drains the previous hot model to COLD (run_phases docstring)
    router_f.run_phases(phases, seed=0, gap_ticks=60)
    # trace-phase peak (the resident-footprint claim); the identity probes
    # below activate all models back-to-back, which would inflate it
    peak_f = router_f.report().peak_resident_replicas

    # identity across the deactivate -> reactivate cycle (greedy, fixed
    # prompt): every model has been through at least one full cycle by now
    identical = True
    for name in models:
        out = router_f.submit(name, PROMPT, 6)
        t0 = time.perf_counter()
        while out.state.value not in ("done", "failed"):
            if router_f.tick() == 0:
                time.sleep(0.001)
            if time.perf_counter() - t0 > 600:
                raise RuntimeError(f"{name} identity probe wedged "
                                   f"(state={out.state.value})")
        identical &= (list(out.generated) == ref[name])
    rep_f = router_f.report()

    f_first = [m["activation_ready_s"][0] for m in rep_f.models.values()]
    f_react = [t for m in rep_f.models.values()
               for t in m["activation_ready_s"][1:]]
    # diagnose a trace that never re-triggered a cold model BEFORE max()
    # on the empty list can obscure it
    assert f_react, "popularity shift never reactivated a cold model"
    rows.append(("fig14.foundry.first_activation_ready_s",
                 max(f_first) * 1e6, f"LOAD;n={len(f_first)}"))
    rows.append(("fig14.foundry.reactivation_ready_s",
                 max(f_react) * 1e6, f"LOAD_from_warm_depot;n={len(f_react)}"))
    rows.append(("fig14.foundry.peak_resident_replicas",
                 float(peak_f), f"{len(models)}_models_scale_to_zero"))
    deact = sum(m["deactivations"] for m in rep_f.models.values())
    rows.append(("fig14.foundry.scale_to_zero_events", float(deact), ""))
    s_f = rep_f.summary()
    rows.append(("fig14.foundry.fallback_compiles",
                 float(s_f["fallback_compiles"]), "must_be_0"))
    rows.append(("fig14.token_identity", 1.0 if identical else 0.0,
                 "deactivate_reactivate_vs_resident"))
    speedup = max(v_act) / max(f_react)
    rows.append(("fig14.activation_speedup", speedup,
                 "vanilla_compile_vs_foundry_reactivation"))
    router_f.deactivate_all()

    # ---- the paper's claim, enforced -------------------------------------
    assert s_f["fallback_compiles"] == 0, "foundry compiled on critical path"
    assert s_f["background_errors"] == 0, "background compiles failed"
    assert identical, "token streams diverged across deactivate->reactivate"
    assert deact >= len(models), "scale-to-zero never engaged"
    assert all(m["activations"] >= 2 for m in rep_f.models.values()), \
        "popularity shift never reactivated a cold model"
    assert speedup > 1.0, (
        f"foundry reactivation ({max(f_react):.2f}s) not faster than "
        f"vanilla activation ({max(v_act):.2f}s)")
    assert rep_v.peak_resident_replicas >= len(models)
    assert peak_f <= rep_v.peak_resident_replicas

    headline = {
        "activation_speedup": speedup,
        "vanilla_activation_ready_s": max(v_act),
        "foundry_reactivation_ready_s": max(f_react),
        "vanilla_peak_resident_replicas": rep_v.peak_resident_replicas,
        "foundry_peak_resident_replicas": peak_f,
        "depot_dedup_ratio": st["dedup_ratio"],
        "fallback_compiles": s_f["fallback_compiles"],
        "token_identity": bool(identical),
    }
    return rows, headline


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 models, fewer requests (CI smoke)")
    args = ap.parse_args()
    rows, headline = run(quick=args.quick)
    emit(rows, figure="fig14_modelzoo", headline=headline)

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig7,...]
"""
from __future__ import annotations

import os
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")  # silence XLA AOT-loader
                                                    # machine-feature warnings
import argparse
import sys
import time
import traceback

MODULES = [
    "fig7_coldstart", "fig8_breakdown", "fig9_tpot", "fig10_pergraph",
    "fig11_templates", "fig12_rank_stamp", "fig13_autoscale",
    "fig14_modelzoo", "fig15_reshard", "fig16_prefix_cache", "fig17_chaos",
    "fig18_observability", "fig19_disagg", "tab1_storage", "tab2_contention",
]


def select(wanted) -> list:
    """Resolve ``--only`` selectors (prefix or substring per module, e.g.
    ``fig14,tab1``); unknown selectors are an error, not a silent no-op."""
    chosen = []
    for w in wanted:
        hits = [m for m in MODULES if m.startswith(w) or w in m]
        if not hits:
            raise SystemExit(f"--only: {w!r} matches no benchmark module "
                             f"(have: {', '.join(MODULES)})")
        chosen += [m for m in hits if m not in chosen]
    return [m for m in MODULES if m in chosen]  # keep canonical order


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of modules, matched by "
                         "prefix/substring (e.g. --only fig14,tab1)")
    args = ap.parse_args()
    selected = (select([w.strip() for w in args.only.split(",") if w.strip()])
                if args.only else MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            out = mod.run()
        except Exception:
            traceback.print_exc()
            print(f"{name}.FAILED,0,error")
            failures += 1
            continue
        rows, headline = out if isinstance(out, tuple) else (out, None)
        for r_name, us, derived in rows:
            print(f"{r_name},{us:.1f},{derived}")
        print(f"{name}.elapsed,{(time.perf_counter() - t0) * 1e6:.1f},")
        # perf trajectory: merge this figure's metrics into BENCH_results.json
        from benchmarks.common import write_results
        write_results(name, rows, headline=headline)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
